"""``repro.api`` — the single public surface of the NeuroVectorizer
reproduction (paper Fig. 3/4: *end-to-end, code to vectorization*).

One facade drives the whole pipeline with interchangeable decision
methods behind the :class:`Agent` protocol and interchangeable reward
sources behind the :class:`Oracle` protocol::

    from repro.api import NeuroVectorizer

    nv = NeuroVectorizer(cfg, agent="ppo", lr=5e-4, seed=0)
    nv.fit(corpus_sites, total_steps=30_000)     # train vs the oracle
    prog = nv.tune(step_fn, abstract_args)       # extract -> act -> tiles
    print(nv.speedup(prog, sites))               # modelled speedup
    with nv.inject(prog):                        # tuned Pallas BlockSpecs
        step_fn(*real_args)

Swap ``agent="ppo"`` for any registry name (``dtree`` / ``nns`` /
``brute`` / ``random`` / ``polly`` / ``baseline``) and the rest of the
code does not change; swap the default cost-model oracle for
``oracle="measured"`` (or a hand-built :class:`MeasuredEnv`) and rewards
come from wall-clock timings of the compiled Pallas kernels instead of
the analytic model — same protocol, same facade::

    nv = NeuroVectorizer(cfg, agent="ppo", oracle="measured",
                         db_path="measure.jsonl",   # persistent timings
                         transport="pool", workers=4)   # N-worker pool

A fitted facade is a *deployable artifact* (PR 5): ``nv.save(dir)``
persists the config, the agent's trained state and the oracle/transport
recipe; ``NeuroVectorizer.load(dir)`` re-assembles it in a fresh process
with bit-identical tuning decisions.  ``program_store="tiles.jsonl"``
additionally memoizes finished :class:`TileProgram`s keyed by (site set,
agent state fingerprint, oracle backend), so tuning a previously-seen
site set is a lookup — zero agent inferences, zero oracle evaluations::

    nv = NeuroVectorizer.load("artifact/", program_store="programs.jsonl")
    prog = nv.tune_sites(sites)        # first call: inference + store put
    prog = nv.tune_sites(sites)        # same sites: pure lookup

For many concurrent tuning sessions over one shared worker pool (and one
shared program store), move up one altitude to
:class:`repro.service.TuningService`.

Import tiers — ``__all__`` below documents the *supported* surface:

* **facade + protocol tier** (use this): :class:`NeuroVectorizer`,
  :class:`Agent`/:class:`Oracle`/:class:`MeasureTransport`, the
  registries (``make_agent``/``make_measured_env``/``make_transport``),
  :class:`TileProgram` + ``inject``/``program_speedup``, the artifact
  layer (``save_agent``/``load_agent``/:class:`ProgramStore`) and the
  service tier (:class:`TuningService`).
* **legacy deep-import tier**: concrete agent classes and per-method
  helpers (``PPOAgent``, ``brute_force_labels``, ...) remain importable
  from here for existing callers, but new code should reach them through
  the registries; they are deliberately *not* in ``__all__`` any more.
  (The deprecated ``polly_action`` shim completed its removal cycle in
  PR 6 — use ``make_agent("polly", cfg)``.)
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Optional, Sequence, Union

from repro.artifacts import (ArtifactError, ProgramStore, agent_fingerprint,
                             load_agent, open_program_store, program_key,
                             save_agent, tune_through_store)
from repro.configs.neurovec import (DEFAULT, NeuroVecConfig, cfg_from_dict,
                                    cfg_to_dict)
from repro.core.agents import (AGENT_NAMES, BaselineHeuristicAgent,
                               BruteForceAgent, DecisionTreeAgent, NNSAgent,
                               PPOAgent, PollyAgent, RandomAgent,
                               brute_force_action, brute_force_costs,
                               brute_force_labels, default_embed_fn,
                               make_agent, n_evaluations)
from repro.core.env import (ActionSpace, CostModelEnv, MeasuredEnv,
                            set_strict_actions)
from repro.core.extractor import extract_arch_sites, extract_sites
from repro.core.protocols import (Agent, AsyncOracle, MeasureTransport,
                                  Oracle, resolve_health)
from repro.core.vectorizer import (TileProgram, baseline_program, inject,
                                   program_speedup, tune, tune_step_fn)
from repro.measure import (TRANSPORT_NAMES, CachedMeasureFn,
                           InProcessTransport, MeasureDB, MeasureRunner,
                           TransportMeasureFn, WorkerPoolTransport,
                           make_measured_env, make_transport,
                           resolve_surrogate)
from repro.obs import MetricsRegistry, ObsHandle, Tracer, get_registry
from repro.obs import resolve_obs as _resolve_obs
from repro.obs import to_chrome_trace
from repro.obs.instrument import (instrument_oracle_stack,
                                  instrument_program_store)
from repro.service import SessionHandle, TuningService
from repro.surrogate import (SurrogateModel, SurrogateOracle, load_surrogate,
                             save_surrogate, train_from_db)

__all__ = [
    # -- facade + protocol tier: the supported public surface ---------------
    "NeuroVectorizer",
    "Agent", "Oracle", "MeasureTransport", "AsyncOracle",
    "AGENT_NAMES", "make_agent", "default_embed_fn",
    "NeuroVecConfig", "DEFAULT", "ActionSpace",
    "CostModelEnv", "MeasuredEnv", "set_strict_actions",
    "make_measured_env", "make_transport", "TRANSPORT_NAMES",
    "TileProgram", "baseline_program", "inject", "program_speedup",
    "extract_sites", "extract_arch_sites",
    "TuningService", "SessionHandle",
    # learned cost model + measurement pruning (PR 7)
    "SurrogateModel", "SurrogateOracle", "train_from_db",
    "save_surrogate", "load_surrogate", "resolve_surrogate",
    # artifact layer (PR 5): checkpoints + warm-start program store
    "ArtifactError", "save_agent", "load_agent", "agent_fingerprint",
    "ProgramStore", "program_key",
    # observability substrate (PR 8): metrics registry + span tracing
    "MetricsRegistry", "get_registry", "Tracer", "to_chrome_trace",
    # NOTE: the legacy deep-import tier (concrete agent classes
    # PPOAgent/BruteForceAgent/..., brute_force_* helpers,
    # MeasureRunner/MeasureDB/CachedMeasureFn/InProcessTransport/
    # WorkerPoolTransport/TransportMeasureFn, tune/tune_step_fn) stays
    # importable from this module for existing callers but is no longer
    # part of the documented surface.
]


_FACADE_FORMAT = "neurovectorizer-facade"


class NeuroVectorizer:
    """The end-to-end facade: extract → fit → tune → inject.

    The reward source and its execution backend compose as a matrix —
    every cell speaks the same :class:`Oracle` protocol, so agents and
    the rest of the pipeline never branch on the choice:

    ==================  ======================  ===========================
    ``oracle=``         ``transport=``          rewards come from
    ==================  ======================  ===========================
    ``None`` / "model"  (must be unset)         the analytic cost model,
                                                ``CostModelEnv``
    ``"measured"``      ``None`` / "inproc"     wall-clock kernel timings
                                                in *this* process
    ``"measured"``      "pool", ``workers=N``   timings fanned out to N
                                                subprocess workers
                                                (``WorkerPoolTransport``)
    ``"measured"``      "socket", ``hosts=``    timings shipped to remote
                                                ``serve-worker`` hosts
                                                (``repro.fleet``
                                                ``SocketTransport``)
    ``"measured"``      a ``MeasureTransport``  timings through your
                                                transport (borrowed — the
                                                facade won't close it)
    ``"surrogate"``     (must be unset)         the learned cost model
                                                (``SurrogateOracle``) —
                                                trained from ``db_path``
                                                or loaded via
                                                ``surrogate=``
    an ``Oracle``       (must be unset)         your oracle, verbatim
    ==================  ======================  ===========================

    ``oracle="measured"`` additionally takes ``prune_topk=N`` +
    optionally ``surrogate=`` (a trained ``SurrogateModel``, a checkpoint
    dir, or ``None`` to train from the DB): the surrogate ranks each
    site's legal grid and only the top-N candidates are ever timed, the
    rest priced by the surrogate (``env.pruned_pairs`` counts the
    savings).

    Parameters
    ----------
    cfg:    the :class:`NeuroVecConfig` (action space, PPO and penalty
            hyperparameters).
    agent:  a registry name (``"ppo"``, ``"brute"``, ...) or an already
            constructed :class:`Agent`.  Extra ``agent_kwargs`` flow to
            ``make_agent`` (e.g. ``lr=``, ``mode=``, ``embed_fn=``).
    oracle: a row of the matrix above.  ``"measured"`` assembles
            :func:`repro.measure.make_measured_env` — real hardware on
            TPU/GPU, interpret-mode Pallas on CPU.
    transport: a column of the matrix above (``oracle="measured"`` only).
    workers: pool size for ``transport="pool"``.
    hosts:  ``serve-worker`` addresses (``["host:port", ...]``) for
            ``transport="socket"``.
    db_path: persistent timing-DB path for ``oracle="measured"``
            (repeat runs against the same path re-time nothing — under
            any transport).  A ``fleet://host:port`` path attaches the
            shared ``serve-artifacts`` timing store instead of a local
            file; the same scheme works for ``program_store=``.
    oracle_kwargs: extra :class:`repro.measure.MeasureRunner` options for
            ``oracle="measured"`` (``reps=``, ``warmup=``, ``max_dim=``,
            ``interpret=``...) — applied per worker under the pool.
    program_store: a :class:`ProgramStore` (borrowed) or a path (opened
            and owned by this facade): finished tile programs are
            memoized per (site set, agent state, oracle backend), so
            ``tune_sites`` on a previously-tuned site set is a pure
            lookup — zero agent inferences, zero oracle evaluations.
            ``agent_inferences`` / ``store_hits`` / ``store_misses``
            count what actually ran.

    A facade that built a measured oracle owns its transport: call
    :meth:`close` (or use the facade as a context manager) to release
    pool workers and the DB/store file handles.  A closed facade raises
    ``RuntimeError`` on further ``fit``/``tune`` calls rather than
    surfacing an opaque queue error from the released transport.  For
    many concurrent sessions over one shared pool, use
    :class:`repro.service.TuningService`.
    """

    def __init__(self, cfg: NeuroVecConfig = DEFAULT,
                 agent: Union[str, Agent] = "ppo",
                 oracle: Union[str, Oracle, None] = None, seed: int = 0,
                 db_path: Optional[str] = None,
                 oracle_kwargs: Optional[dict] = None,
                 transport: Union[str, MeasureTransport, None] = None,
                 workers: Optional[int] = None,
                 hosts=None,
                 program_store: Union[str, ProgramStore, None] = None,
                 prune_topk: Optional[int] = None,
                 surrogate: Union[str, SurrogateModel, None] = None,
                 metrics: Union[MetricsRegistry, bool, None] = None,
                 trace: Union[str, Tracer, None] = None,
                 **agent_kwargs):
        self.cfg = cfg
        self._owns_oracle = False
        self._closed = False
        # obs substrate (PR 8): metrics default to the shared process-wide
        # registry (metrics=False disables); tracing is off unless trace=
        # names a JSONL path (owned — closed with the facade) or passes a
        # repro.obs.Tracer (borrowed)
        self.registry, self.tracer, self._owns_tracer = \
            _resolve_obs(metrics, trace)
        if oracle == "measured":
            self.oracle: Oracle = make_measured_env(
                cfg, db_path=db_path, seed=seed, transport=transport,
                workers=workers, hosts=hosts, prune_topk=prune_topk,
                surrogate=surrogate, **(oracle_kwargs or {}))
            # a borrowed MeasureTransport instance is not ours to close
            self._owns_oracle = transport is None or isinstance(transport,
                                                                str)
        elif oracle == "surrogate":
            if oracle_kwargs or transport is not None or \
                    workers is not None or hosts is not None:
                raise ValueError("oracle_kwargs/transport/workers/hosts "
                                 "apply only to oracle='measured'")
            if prune_topk is not None:
                raise ValueError("prune_topk applies only to "
                                 "oracle='measured' (a surrogate oracle "
                                 "performs no measurements to prune)")
            model = resolve_surrogate(surrogate, db=db_path)
            if model is None:
                raise ValueError(
                    "oracle='surrogate' needs a trained model: pass "
                    "surrogate= (a SurrogateModel or checkpoint dir) or "
                    "db_path= pointing at a MeasureDB with enough finite "
                    "records to train from")
            self.oracle = SurrogateOracle(cfg, model, seed=seed)
        else:
            if db_path is not None or oracle_kwargs or \
                    transport is not None or workers is not None or \
                    hosts is not None:
                raise ValueError("db_path/oracle_kwargs/transport/workers/"
                                 "hosts apply only to oracle='measured'")
            if prune_topk is not None or surrogate is not None:
                raise ValueError("prune_topk/surrogate apply only to "
                                 "oracle='measured' or oracle='surrogate'")
            if oracle is None or oracle == "model":
                self.oracle = CostModelEnv(cfg, seed=seed)
            elif isinstance(oracle, str):
                raise ValueError(f"unknown oracle {oracle!r}: expected "
                                 f"'model', 'measured', or 'surrogate'")
            else:
                self.oracle = oracle
        self.agent: Agent = (make_agent(agent, cfg, seed=seed,
                                        **agent_kwargs)
                             if isinstance(agent, str) else agent)
        self._owns_store = isinstance(program_store, str)
        self.program_store: Optional[ProgramStore] = (
            open_program_store(program_store) if self._owns_store
            else program_store)
        # warm-start observability: how many sites actually went through
        # agent.act vs. were answered from the store
        self.agent_inferences = 0
        self.store_hits = 0
        self.store_misses = 0
        # the re-assembly recipe nv.save() persists (strings only; a
        # hand-built oracle/transport/agent is recorded as non-portable)
        self._spec = {
            "agent": agent if isinstance(agent, str) else None,
            "agent_kwargs": agent_kwargs if isinstance(agent, str) else {},
            "oracle": (oracle if isinstance(oracle, str) or oracle is None
                       else "custom"),
            "transport": (transport if isinstance(transport, str)
                          or transport is None else "custom"),
            "workers": workers, "db_path": db_path,
            "hosts": list(hosts) if hosts else None,
            "oracle_kwargs": dict(oracle_kwargs or {}), "seed": seed,
            "prune_topk": prune_topk,
            # a live SurrogateModel instance is not serializable; measured
            # facades retrain from the DB on load, surrogate facades
            # require an explicit surrogate= override
            "surrogate": (surrogate if isinstance(surrogate, str)
                          or surrogate is None else "custom"),
        }
        # wire the oracle stack (env counters, breaker gauge, transport,
        # DB, surrogate) and the program store into the registry, and open
        # the facade's root span — ended by close()
        self._obs = ObsHandle(self.registry)
        self._obs.adopt(instrument_oracle_stack(self.oracle, self.registry,
                                                self.tracer))
        self._obs.adopt(instrument_program_store(self.program_store,
                                                 self.registry))
        self._m_fit_s = self.registry.histogram(
            "facade_fit_seconds", "NeuroVectorizer.fit() latency")
        self._m_tune_s = self.registry.histogram(
            "facade_tune_seconds", "NeuroVectorizer.tune_sites() latency")
        self._span = self.tracer.begin("session", detached=True,
                                       kind="facade",
                                       agent=self.agent.name)

    # -- training ----------------------------------------------------------
    def fit(self, corpus_sites: Sequence, **fit_kwargs) -> "NeuroVectorizer":
        """Fit the agent against this facade's oracle (RL training, brute
        labelling, or a no-op for search-free methods).  Extra kwargs flow
        to the agent (e.g. ``total_steps=`` for ppo, ``labels=`` for
        nns/dtree)."""
        self._check_open("fit")
        corpus_sites = list(corpus_sites)
        t0 = time.monotonic()
        with self.tracer.span("fit", parent=self._span,
                              n_sites=len(corpus_sites)):
            self.agent.fit(corpus_sites, self.oracle, **fit_kwargs)
        self._m_fit_s.observe(time.monotonic() - t0)
        return self

    # -- tuning ------------------------------------------------------------
    def tune(self, step_fn, abstract_args: Sequence = ()) -> TileProgram:
        """Extract kernel sites from ``step_fn`` traced over
        ``abstract_args`` and tune them (greedy inference, paper §4.2)."""
        return self.tune_sites(extract_sites(step_fn, *abstract_args))

    def tune_sites(self, sites: Sequence) -> TileProgram:
        self._check_open("tune")
        sites = list(sites)
        t0 = time.monotonic()
        with self.tracer.span("tune", parent=self._span,
                              n_sites=len(sites)) as sp:
            prog, hit = tune_through_store(sites, self.agent,
                                           self.oracle.space,
                                           self.oracle, self.program_store)
            sp.set(store_hit=bool(hit))
        self._m_tune_s.observe(time.monotonic() - t0)
        if self.program_store is not None and sites:
            if hit:
                self.store_hits += 1
            else:
                self.store_misses += 1
        if not hit:
            self.agent_inferences += len(sites)
        return prog

    def tune_arch(self, arch: str, batch: int = 8,
                  seq: int = 2048) -> TileProgram:
        """Tune every site of one training step of a named architecture."""
        return self.tune_sites(extract_arch_sites(arch, batch=batch,
                                                  seq=seq))

    # -- deployment --------------------------------------------------------
    def inject(self, program: TileProgram, interpret: bool = False):
        """Context manager: run model code with the tuned tiles routed
        through the Pallas kernels (the pragma-injection analogue)."""
        return inject(program, interpret=interpret)

    def baseline(self, sites: Sequence) -> TileProgram:
        return baseline_program(list(sites))

    def speedup(self, program: TileProgram, sites: Sequence) -> float:
        """Aggregate speedup of ``program`` over the heuristic baseline,
        priced by this facade's oracle semantics."""
        return program_speedup(program, list(sites), env=self.oracle)

    def health(self) -> str:
        """``ok | degraded | down`` of this facade's reward path.

        ``degraded`` means tuning still completes but rewards come from
        the analytic cost model (the :class:`MeasuredEnv` circuit
        breaker opened, or the transport collapsed under an oracle that
        can fall back); the model-oracle facade is always ``ok``."""
        fn = getattr(self.oracle, "measure_fn", None)
        return resolve_health(self.oracle, getattr(fn, "transport", None))

    # -- persistence (PR 5) -------------------------------------------------
    def save(self, path: str) -> str:
        """Persist this facade as an artifact directory: the config, the
        agent's full trained state (``repro.artifacts`` format, atomic +
        fingerprinted) and the oracle/transport re-assembly recipe.
        Returns the agent-state fingerprint.

        A hand-built :class:`Oracle`/transport instance cannot be
        serialized — :meth:`load` will then require an explicit
        ``oracle=``/``transport=`` override."""
        spec = dict(self._spec)
        if spec["agent"] is None:
            # an agent passed as an instance: record its registry name so
            # load() can reconstruct it before restoring the state.  The
            # embedding-based methods are the exception — a hand-passed
            # embed_fn is a live callable outside state_dict(), and
            # reconstructing with the default embedder would *silently*
            # change act(); refuse rather than break the bitwise guarantee.
            if isinstance(self.agent, (NNSAgent, DecisionTreeAgent)):
                raise ArtifactError(
                    f"cannot record the construction of a hand-built "
                    f"{type(self.agent).__name__} (its embed_fn is a live "
                    f"callable) — construct via agent="
                    f"{self.agent.name!r} on the facade, or pass agent= "
                    f"to NeuroVectorizer.load()")
            spec["agent"] = self.agent.name
        payload = {"format": _FACADE_FORMAT, "version": 1,
                   "cfg": cfg_to_dict(self.cfg), **spec}
        try:
            blob = json.dumps(payload, indent=1)
        except TypeError as e:
            raise ArtifactError(
                f"facade spec is not serializable ({e}); agent_kwargs and "
                f"oracle_kwargs must be plain JSON values to save") from e
        path = str(path)
        tmp = path.rstrip(os.sep) + f".tmp-{os.getpid()}"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        fp = save_agent(self.agent, os.path.join(tmp, "agent"))
        with open(os.path.join(tmp, "facade.json"), "w") as f:
            f.write(blob)
        # manifest last: a partial directory is never restorable
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"format": _FACADE_FORMAT, "version": 1,
                       "agent": payload["agent"], "agent_fingerprint": fp,
                       "time": time.time()}, f, indent=1)
        # never destroy a valid artifact before its replacement has fully
        # landed: move the old directory aside, swing the new one in, then
        # drop the old — a crash mid-save leaves either the old or the new
        # artifact restorable at `path` (or the old one parked at .old-*)
        old = None
        if os.path.isdir(path):
            old = path.rstrip(os.sep) + f".old-{os.getpid()}"
            shutil.rmtree(old, ignore_errors=True)
            os.replace(path, old)
        os.replace(tmp, path)
        if old is not None:
            shutil.rmtree(old, ignore_errors=True)
        return fp

    @classmethod
    def load(cls, path: str,
             agent: Optional[Agent] = None,
             oracle: Union[str, Oracle, None] = None,
             transport: Union[str, MeasureTransport, None] = None,
             workers: Optional[int] = None, hosts=None,
             db_path: Optional[str] = None,
             program_store: Union[str, ProgramStore, None] = None,
             seed: Optional[int] = None,
             prune_topk: Optional[int] = None,
             surrogate: Union[str, SurrogateModel, None] = None,
             **agent_kwargs
             ) -> "NeuroVectorizer":
        """Re-assemble a facade saved by :meth:`save` in a (possibly
        fresh) process: config + agent construction + verified state
        restore + oracle/transport from the recorded recipe.  The loaded
        facade's ``tune_sites`` is bit-identical to the saver's.

        Keyword overrides replace the recorded recipe (e.g. point
        ``db_path`` at a local timing DB, or attach a shared
        ``program_store``); ``agent=`` supplies a pre-constructed agent
        to restore the state into (required when the saved agent cannot
        be rebuilt from the registry, e.g. nns/dtree with a custom
        ``embed_fn``), and ``oracle=``/``transport=`` are required when
        the original facade was built around hand-built instances."""
        path = str(path)
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            raise ArtifactError(f"no restorable facade artifact at "
                                f"{path!r} (manifest.json missing)")
        with open(os.path.join(path, "facade.json")) as f:
            spec = json.load(f)
        if spec.get("format") != _FACADE_FORMAT:
            raise ArtifactError(f"{path!r} is not a facade artifact "
                                f"(format={spec.get('format')!r})")
        cfg = cfg_from_dict(spec["cfg"])
        if spec["oracle"] == "custom" and oracle is None:
            raise ArtifactError(
                "this artifact was saved around a hand-built Oracle, which "
                "cannot be re-assembled automatically — pass oracle= to "
                "load()")
        oracle = spec["oracle"] if oracle is None else oracle
        # pre-PR-7 artifacts carry no pruning fields; a recorded live
        # model ("custom") is not reloadable — measured facades retrain
        # from the DB, a surrogate facade needs an explicit override
        spec_sur = spec.get("surrogate")
        if surrogate is None and spec_sur != "custom":
            surrogate = spec_sur
        kw = {}
        if oracle == "measured":
            # the transport only matters once the resolved oracle needs
            # one — an oracle='model' override never reads it
            if spec["transport"] == "custom" and transport is None:
                raise ArtifactError(
                    "this artifact was saved around a hand-built "
                    "transport — pass transport= to load()")
            kw = {"transport": (spec["transport"] if transport is None
                                else transport),
                  "workers": spec["workers"] if workers is None else workers,
                  "hosts": spec.get("hosts") if hosts is None else hosts,
                  "db_path": spec["db_path"] if db_path is None else db_path,
                  "oracle_kwargs": spec["oracle_kwargs"] or None,
                  "prune_topk": (spec.get("prune_topk")
                                 if prune_topk is None else prune_topk),
                  "surrogate": surrogate}
        elif oracle == "surrogate":
            if spec_sur == "custom" and surrogate is None:
                raise ArtifactError(
                    "this artifact was saved around a live SurrogateModel "
                    "instance, which cannot be re-assembled automatically "
                    "— pass surrogate= (a model or checkpoint dir) to "
                    "load()")
            kw = {"db_path": spec["db_path"] if db_path is None else db_path,
                  "surrogate": surrogate}
        merged_kwargs = {**spec["agent_kwargs"], **agent_kwargs}
        nv = cls(cfg, agent=spec["agent"] if agent is None else agent,
                 oracle=oracle,
                 seed=spec["seed"] if seed is None else seed,
                 program_store=program_store,
                 **kw, **(merged_kwargs if agent is None else {}))
        load_agent(os.path.join(path, "agent"), agent=nv.agent)
        if isinstance(nv.agent, BruteForceAgent):
            # brute captures a live oracle at fit time; re-bind ours so a
            # loaded exhaustive search prices tiles with the same oracle
            nv.agent.oracle = nv.oracle
        return nv

    # -- lifecycle ---------------------------------------------------------
    def _check_open(self, verb: str) -> None:
        if self._closed:
            raise RuntimeError(
                f"cannot {verb}: this NeuroVectorizer is closed (its "
                f"transport/store handles were released) — build a new "
                f"facade or NeuroVectorizer.load() a saved one")

    def close(self) -> None:
        """Release the measured oracle's transport (pool workers, DB file
        handle) and an owned program store, and mark the facade closed:
        subsequent ``fit``/``tune`` calls raise a clear ``RuntimeError``
        instead of an opaque error from the released transport.
        Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._span.end()
        if self._owns_oracle:
            self.oracle.measure_fn.transport.close()
        if self._owns_store and self.program_store is not None:
            self.program_store.close()
        self._obs.close()
        if self._owns_tracer:
            self.tracer.close()

    def __enter__(self) -> "NeuroVectorizer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
