"""Fault tolerance: straggler detection, preemption handling, elastic
re-planning.

On a real multi-pod deployment the runtime (GKE/Borg + libtpu) restarts
failed workers; this module supplies the framework-side pieces that make a
restart cheap and a slow host visible:

* ``StepMonitor`` — per-step wall-time EMA + z-score straggler flags.
* ``PreemptionHandler`` — SIGTERM/SIGINT => checkpoint-and-exit flag.
* ``plan_elastic_mesh`` — given surviving chip count, the largest valid
  (data, model) grid with TP preserved, plus the data re-shard plan.
"""
from __future__ import annotations

import math
import signal
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


class StepMonitor:
    """Per-step wall-time EMA with z-score straggler flags.

    Flags still accumulate in :attr:`events` (the in-process forensic
    record), and — when ``metrics=``/``tracer=`` wire it into the
    ``repro.obs`` substrate — each flag also increments the
    ``straggler_flags_total`` counter and lands in the shared trace file
    as a ``straggler`` instant event, right next to the tune spans it
    stretched."""

    def __init__(self, alpha: float = 0.1, z_thresh: float = 3.0,
                 warmup: int = 5, metrics=None, tracer=None):
        self.alpha = alpha
        self.z = z_thresh
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.events: List[dict] = []
        self._t0: Optional[float] = None
        self._counter = None
        if metrics is not None:
            self._counter = metrics.counter(
                "straggler_flags_total",
                "steps flagged as stragglers by StepMonitor")
        self._tracer = tracer

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> Optional[dict]:
        dt = time.monotonic() - self._t0
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n)
            return None
        z = (dt - self.mean) / (math.sqrt(self.var) + 1e-9) \
            if self.var > 0 else 0.0
        ev = None
        if z > self.z:
            ev = {"step": step, "dt": dt, "mean": self.mean, "z": z,
                  "kind": "straggler"}
            self.events.append(ev)
            if self._counter is not None:
                self._counter.inc()
            if self._tracer is not None:
                self._tracer.event("straggler", step=step, dt=dt,
                                   mean=self.mean, z=z)
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return ev


class PreemptionHandler:
    """Installs SIGTERM/SIGINT handlers; trainer polls ``should_stop``.

    ``on_stop`` is the push-side alternative for consumers with no poll
    loop (e.g. :class:`~repro.service.TuningService`): invoked once from
    the handler after ``should_stop`` is set — drain and close there."""

    def __init__(self, signals=(signal.SIGTERM,), on_stop=None):
        self.should_stop = False
        self._on_stop = on_stop
        self._prev = {}
        for s in signals:
            self._prev[s] = signal.signal(s, self._handle)

    def _handle(self, signum, frame):
        already = self.should_stop
        self.should_stop = True
        if self._on_stop is not None and not already:
            self._on_stop()

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_chips: int
    global_batch: int


def plan_elastic_mesh(healthy_chips: int, model_parallel: int,
                      global_batch: int, multi_pod: bool = False
                      ) -> ElasticPlan:
    """Largest power-of-two data axis that fits the surviving chips with TP
    preserved (TP degree is baked into weight shardings; DP is elastic)."""
    assert healthy_chips >= model_parallel, "cannot preserve TP degree"
    dp = healthy_chips // model_parallel
    dp = 2 ** int(math.log2(dp))
    used = dp * model_parallel
    # keep per-replica batch constant: shrink the global batch with DP
    gb = global_batch
    while gb % dp:
        gb -= 1
    if multi_pod and dp % 2 == 0:
        return ElasticPlan((2, dp // 2, model_parallel),
                           ("pod", "data", "model"),
                           healthy_chips - used, gb)
    return ElasticPlan((dp, model_parallel), ("data", "model"),
                       healthy_chips - used, gb)
