"""End-to-end driver: train a small LM for a few hundred steps with the
NeuroVectorizer-tuned kernels injected (the deployment mode of §4.2),
tuned through the ``repro.api`` facade.

    PYTHONPATH=src python examples/autotune_and_train.py [--steps 300]

Uses the reduced xLSTM config (~1M params smoke / scale up with --d-model);
on this CPU container the Pallas kernels run in interpret mode, on TPU they
compile natively — the driver is identical.
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--agent", default="ppo",
                    help="any repro.api registry name (ppo, brute, ...)")
    ap.add_argument("--rl-steps", type=int, default=4000)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    from repro.api import NeuroVecConfig, NeuroVectorizer, extract_arch_sites
    from repro.core import dataset
    from repro.launch import train as train_mod

    print("== tune ==")
    cfg = NeuroVecConfig(train_batch=500, sgd_minibatch=125, ppo_epochs=6)
    nv = NeuroVectorizer(cfg, agent=args.agent, seed=0,
                         **({"lr": 5e-4} if args.agent == "ppo" else {}))
    sites = extract_arch_sites(args.arch, batch=8, seq=2048)
    fit_kw = ({"total_steps": args.rl_steps} if args.agent == "ppo" else {})
    nv.fit(dataset.generate(1200, seed=0, base=sites), **fit_kw)
    prog = nv.tune_sites(sites)
    prog.save("/tmp/repro_tiles.json")
    print(f"saved TileProgram with {len(prog.tiles)} sites "
          f"(modelled speedup {nv.speedup(prog, sites):.2f}x)")

    print("== train with tuned kernels + checkpoint/restart ==")
    losses = train_mod.main([
        "--arch", args.arch, "--steps", str(args.steps), "--batch", "8",
        "--seq", "64", "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
    ])
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"e2e OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
