"""Service-oriented autotuning: concurrent sessions over one worker pool.

``repro.service.TuningService`` owns a shared measurement transport —
here a ``WorkerPoolTransport`` fanning (site, tiles) batches out to N
subprocess workers — and hands out sessions, each pairing an agent with
an oracle view.  Two sessions tune below (PPO trained on measured
rewards, and brute force sweeping the same grid *concurrently*); their
overlapping (site, tiles) keys coalesce inside the transport and every
timing streams into one persistent ``MeasureDB``.

    PYTHONPATH=src python examples/service_autotune.py \
        [--workers 2] [--db /tmp/service_measure.jsonl] [--steps 48]

Run it twice with the same ``--db`` and the second run performs zero
kernel timings — the CI smoke for the whole service→pool→DB chain.
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, "examples")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2,
                    help="worker-pool size (subprocesses)")
    ap.add_argument("--db", default="/tmp/repro_service_measure.jsonl",
                    help="persistent measurement-DB path shared by every "
                         "session")
    ap.add_argument("--steps", type=int, default=48,
                    help="PPO environment steps for the RL session")
    ap.add_argument("--reps", type=int, default=1,
                    help="timing repetitions per (site, tile) pair")
    ap.add_argument("--prune-topk", type=int, default=None,
                    help="only time each site's top-K surrogate-ranked "
                         "tile candidates per session; the rest are priced "
                         "by a learned cost model trained from --db "
                         "(needs a warm DB — run once without it first)")
    ap.add_argument("--trace-out", default=None,
                    help="append the session span tree (session -> fit -> "
                         "tune -> submit/drain) to this JSONL trace file "
                         "(repro.obs)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the service's final metrics snapshot to "
                         "this JSON file")
    ap.add_argument("--chaos", action="store_true",
                    help="after the normal run, hard-kill the transport "
                         "and prove tuning degrades to the cost model "
                         "(prints the resulting health line)")
    args = ap.parse_args(argv)
    if args.reps < 1:
        ap.error(f"--reps must be >= 1, got {args.reps}")
    if args.prune_topk is not None and args.prune_topk < 1:
        ap.error(f"--prune-topk must be >= 1, got {args.prune_topk}")

    from measured_autotune import demo_sites, small_cfg
    from repro.api import TileProgram, TuningService

    cfg = small_cfg()
    sites = demo_sites()

    with TuningService(cfg, transport="pool", workers=args.workers,
                       db_path=args.db, reps=args.reps, warmup=1,
                       trace=args.trace_out) as svc:
        print(f"== TuningService: pool of {args.workers} workers "
              f"({svc.transport.backend_key}) ==")
        rl = svc.open_session(agent="ppo", oracle="measured",
                              prune_topk=args.prune_topk)
        sweep = svc.open_session(agent="brute", oracle="measured",
                                 prune_topk=args.prune_topk)

        # brute's exhaustive grid sweep measures concurrently with PPO
        # training — overlapping pairs coalesce inside the transport
        sweep_fut = sweep.fit(sites).tune_async(sites)
        rl.fit(sites, total_steps=args.steps)
        rl_prog = rl.tune(sites)
        sweep_prog = sweep_fut.result()
        assert isinstance(rl_prog, TileProgram)
        assert len(rl_prog.tiles) == len(sweep_prog.tiles) == len(sites)

        for handle, prog in ((rl, rl_prog), (sweep, sweep_prog)):
            s = handle.stats()
            print(f"[{s['session']}] agent={s['agent']} "
                  f"tunes={s['session_tunes_total']} "
                  f"sites={s['session_sites_tuned_total']} "
                  f"fit {s['session_fit_seconds_total']:.2f}s "
                  f"tune {s['session_tune_seconds_total']:.2f}s "
                  f"| transport Δ: "
                  f"{s['transport']['transport_timed_pairs_total']} timed, "
                  f"{s['transport']['transport_hits_total']} hits, "
                  f"{s['transport']['transport_coalesced_total']} coalesced")
        for k in sorted(sweep_prog.tiles):
            print(f"  {k}: rl={rl_prog.tiles[k]} brute={sweep_prog.tiles[k]}")

        if args.chaos:
            # graceful degradation, end to end: the transport dies hard,
            # yet the session still tunes — the MeasuredEnv circuit
            # breaker opens and prices with the analytic cost model
            print("== chaos: closing the measurement transport mid-life ==")
            svc.transport.close()
            env = rl.oracle.oracle          # the session's MeasuredEnv
            env.clear_result_cache()        # force re-pricing on the ruin
            chaos_prog = rl.tune(sites)
            assert len(chaos_prog.tiles) == len(sites)
            from repro.api import program_speedup
            sp = program_speedup(chaos_prog, sites, env=env)
            print(f"[chaos] health: {rl.health()} — tuned "
                  f"{len(chaos_prog.tiles)} sites via cost-model fallback "
                  f"(modelled speedup {sp:.2f}x, breaker_open="
                  f"{env.breaker_open})")

        snap = svc.registry.snapshot()
        n_tunes = sum(v for k, v in snap.items()
                      if k.startswith("session_tunes_total"))
        print(f"obs: {len(snap)} metric series, "
              f"{int(n_tunes)} tunes recorded"
              + (f", trace -> {args.trace_out}" if args.trace_out else ""))
        if args.metrics_out:
            import json
            with open(args.metrics_out, "w") as f:
                json.dump(snap, f, indent=1, default=str)
        st = svc.transport.stats()
    print(f"measurements: {st['transport_timed_pairs_total']} timed, "
          f"{st['transport_hits_total']} DB hits, "
          f"{st['transport_coalesced_total']} coalesced, "
          f"{st['transport_retries_total']} retries "
          f"across {st['pool_workers_count']} workers — rerun with the "
          f"same --db and timed goes to 0")
    return rl_prog, sweep_prog


if __name__ == "__main__":
    main()
