"""Cross-host tuning fleet end to end (``repro.fleet``).

The client in this process never times a kernel: measurements ship over
TCP to ``serve-worker`` daemons, and both persistent stores (the timing
DB and the tuned-program store) live behind one shared
``serve-artifacts`` daemon that every fleet client subscribes to.

Start the daemons (one terminal each, or backgrounded):

    PYTHONPATH=src python -m repro.fleet serve-worker \\
        --port 7761 --transport pool --workers 2 --reps 1
    PYTHONPATH=src python -m repro.fleet serve-artifacts \\
        --port 7762 --measure-db /tmp/fleet_measure.jsonl \\
        --program-store /tmp/fleet_programs.jsonl

then run this twice:

    PYTHONPATH=src python examples/fleet_autotune.py \\
        --hosts 127.0.0.1:7761 --artifacts 127.0.0.1:7762 [--steps 48]

Run 1 times every (site, tile) pair on the serve-worker hosts and a
*second, independent* subscriber in this process observes the finished
tile program arrive by push — without reopening the store.  Run 2 finds
the shared DB warm (zero timings fleet-wide) and the program store
answers the whole tune by lookup.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, "examples")

from measured_autotune import demo_sites, small_cfg  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", required=True,
                    help="comma-separated serve-worker host:port list")
    ap.add_argument("--artifacts", required=True,
                    help="serve-artifacts host:port (shared MeasureDB + "
                         "ProgramStore)")
    ap.add_argument("--steps", type=int, default=48,
                    help="PPO environment steps (measured rewards)")
    ap.add_argument("--agent", default="ppo",
                    help="any repro.api registry name (ppo, brute, ...)")
    ap.add_argument("--out", default="/tmp/repro_fleet_tiles.json")
    args = ap.parse_args(argv)

    from repro.api import NeuroVectorizer, TileProgram
    from repro.fleet import RemoteProgramStore

    hosts = [h.strip() for h in args.hosts.split(",") if h.strip()]
    art = f"fleet://{args.artifacts}"
    cfg = small_cfg()
    sites = demo_sites()

    # an independent subscriber, opened BEFORE tuning: if the tune below
    # produces a fresh program, this client must see it arrive by push —
    # the serving-process half of fleet store invalidation
    watcher = RemoteProgramStore(art)
    baseline_entries = len(watcher)

    nv = NeuroVectorizer(cfg, agent=args.agent, oracle="measured", seed=0,
                         transport="socket", hosts=hosts,
                         db_path=art, program_store=art)
    t = nv.oracle.measure_fn.transport
    print(f"== fleet tune: {len(hosts)} host(s) "
          f"[{', '.join(hosts)}], artifacts {args.artifacts}, "
          f"backend {t.backend_key} ==")
    fit_kw = ({"total_steps": args.steps} if args.agent == "ppo" else {})
    nv.fit(sites, **fit_kw)
    prog = nv.tune_sites(sites)
    assert isinstance(prog, TileProgram) and len(prog.tiles) == len(sites)
    prog.save(args.out)
    print(f"tuned {len(prog.tiles)} sites -> {args.out}")

    if nv.store_hits:
        print(f"store warm: {nv.store_hits} tune(s) answered by shared "
              f"program-store lookup ({nv.agent_inferences} agent "
              f"inferences)")
    else:
        # fresh program: wait for the server to push it to the watcher
        deadline = time.time() + 10.0
        while time.time() < deadline and (
                watcher.pushes_received == 0
                or len(watcher) <= baseline_entries):
            time.sleep(0.05)
        assert watcher.pushes_received >= 1, \
            "watcher never received the push"
        print("push-invalidation: serving client observed the tuned "
              "program without reopening the store "
              f"({watcher.pushes_received} push(es), "
              f"{len(watcher)} entries)")

    st = t.stats()
    print(f"fleet hosts: {st['fleet_hosts_live']}/{st['fleet_hosts_count']}"
          f" live, {st['fleet_reconnects_total']} reconnects, health "
          f"{st['health']}")
    print(f"measurements: {st['transport_timed_pairs_total']} timed, "
          f"{st['transport_hits_total']} DB hits, "
          f"{st['transport_misses_total']} misses, "
          f"{st['transport_coalesced_total']} coalesced "
          f"(hit rate {st['transport_hit_ratio']:.2f}) — rerun and timed "
          f"goes to 0")
    watcher.close()
    nv.close()
    return prog


if __name__ == "__main__":
    main()
