"""Measured autotuning end to end: train PPO against *wall-clock* rewards.

This is the paper's actual loop (eq. 2 — the agent learns from measured
execution time, not a cost model): every reward below comes from
compiling and timing the Pallas kernels via ``oracle="measured"``.  On
TPU/GPU the kernels compile natively; on CPU they run in Pallas interpret
mode with capped shapes, so this exact script is the CI smoke for the
whole measure→reward→train→deploy chain.

    PYTHONPATH=src python examples/measured_autotune.py \
        [--steps 96] [--db /tmp/measure.jsonl] [--agent ppo] \
        [--transport pool --workers 2]

Run it twice with the same ``--db`` and the second run performs zero
kernel timings — every (site, tile) pair is served from the persistent
measurement database (under either transport: the pool streams its
results into the same DB).  For the session-oriented service on top,
see ``examples/service_autotune.py``.
"""
import argparse
import sys

sys.path.insert(0, "src")


def small_cfg():
    """A compact action space: measured tuning sweeps real kernels, so the
    demo keeps the grid small enough for interpret-mode CI (~tens of
    pairs, each timed once ever thanks to the DB)."""
    from repro.api import NeuroVecConfig
    return NeuroVecConfig(
        bm_choices=(16, 32, 64), bn_choices=(128,), bk_choices=(128,),
        bq_choices=(64, 128), bkv_choices=(128,), chunk_choices=(32, 64),
        train_batch=32, sgd_minibatch=16, ppo_epochs=2, lr=5e-4)


def demo_sites():
    from repro.models.compute import KernelSite
    return [
        KernelSite(site="ex.qkv", kind="matmul", m=64, n=128, k=256),
        KernelSite(site="ex.ffn", kind="matmul", m=128, n=128, k=128),
        KernelSite(site="ex.attn", kind="attention", m=128, n=64, k=128,
                   batch=2, causal=True),
        KernelSite(site="ex.scan", kind="chunk_scan", m=64, n=32, k=16,
                   batch=2),
    ]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=96,
                    help="PPO environment steps (measured rewards)")
    ap.add_argument("--agent", default="ppo",
                    help="any repro.api registry name (ppo, brute, ...)")
    ap.add_argument("--db", default="/tmp/repro_measure.jsonl",
                    help="persistent measurement-DB path")
    ap.add_argument("--reps", type=int, default=1,
                    help="timing repetitions per (site, tile) pair")
    ap.add_argument("--prune-topk", type=int, default=None,
                    help="only time each site's top-K surrogate-ranked "
                         "tile candidates; the rest are priced by a "
                         "learned cost model trained from --db "
                         "(needs a warm DB — run once without it first)")
    ap.add_argument("--transport", choices=("inproc", "pool"),
                    default="inproc",
                    help="measure in this process or across a subprocess "
                         "worker pool")
    ap.add_argument("--workers", type=int, default=2,
                    help="pool size for --transport pool")
    ap.add_argument("--out", default="/tmp/repro_measured_tiles.json")
    args = ap.parse_args(argv)
    if args.reps < 1:
        ap.error(f"--reps must be >= 1, got {args.reps}")
    if args.prune_topk is not None and args.prune_topk < 1:
        ap.error(f"--prune-topk must be >= 1, got {args.prune_topk}")

    from repro.api import NeuroVectorizer, TileProgram

    cfg = small_cfg()
    sites = demo_sites()
    nv = NeuroVectorizer(cfg, agent=args.agent, oracle="measured", seed=0,
                         db_path=args.db, transport=args.transport,
                         workers=(args.workers
                                  if args.transport == "pool" else None),
                         prune_topk=args.prune_topk,
                         oracle_kwargs=dict(reps=args.reps, warmup=1))
    print(f"== fit {args.agent} vs measured oracle "
          f"(transport={args.transport}, "
          f"{nv.oracle.measure_fn.transport.backend_key}) ==")
    fit_kw = ({"total_steps": args.steps} if args.agent == "ppo" else {})
    nv.fit(sites, **fit_kw)

    prog = nv.tune_sites(sites)
    assert isinstance(prog, TileProgram) and len(prog.tiles) == len(sites)
    prog.save(args.out)

    print(f"tuned {len(prog.tiles)} sites -> {args.out}")
    for k, t in prog.tiles.items():
        print(f"  {k}: tiles={t}")
    print(f"measured speedup vs heuristic baseline: "
          f"{nv.speedup(prog, sites):.2f}x")
    st = nv.oracle.measure_fn.transport.stats()
    print(f"measurements: {st['transport_timed_pairs_total']} timed, "
          f"{st['transport_hits_total']} DB hits, "
          f"{st['transport_misses_total']} misses, "
          f"{st['transport_coalesced_total']} coalesced "
          f"(hit rate {st['transport_hit_ratio']:.2f}) — rerun with the "
          f"same --db and timed goes to 0")
    if args.prune_topk is not None:
        state = ("active" if nv.oracle.prune_active
                 else "inactive (DB too cold to train the surrogate)")
        print(f"pruning top-{args.prune_topk}: {state}, "
              f"{nv.oracle.pruned_pairs} pairs surrogate-priced")
    nv.close()                 # release pool workers / the DB file handle
    return prog


if __name__ == "__main__":
    main()
