"""Serving under an SLO: N concurrent clients against one admission queue.

``TuningService(serving=...)`` runs every session ``tune``/``tune_async``
through a deadline-aware :class:`repro.serving.Server`: concurrent
requests coalesce into batches (model-oracle tunes become ONE fused
device dispatch per batch), each request carries an SLO budget, and past
``max_queue`` depth the server *sheds* with a typed ``QueueFull``
instead of silently blowing every queued deadline behind it.

    PYTHONPATH=src python examples/serving_autotune.py \\
        [--clients 4] [--slo-ms 200] [--rounds 6]

Phase 1 (nominal load) drives ``--clients`` threads through one server
and prints client-observed p50/p99 against the SLO with zero shed.
Phase 2 (overload) bursts requests at a 2-deep queue and prints the
nonzero shed count — admission control working as designed.  This is
the CI smoke for the serving path.
"""
import argparse
import sys
import threading
import time

sys.path.insert(0, "src")
sys.path.insert(0, "examples")


def client_sites(i, n=3):
    from repro.models.compute import KernelSite
    return [KernelSite(site=f"cl{i}.mm{j}", kind="matmul",
                       m=32 * (j + 1), n=128, k=128) for j in range(n)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent client threads (sessions)")
    ap.add_argument("--slo-ms", type=float, default=200.0,
                    help="per-request SLO budget at nominal load")
    ap.add_argument("--rounds", type=int, default=6,
                    help="tune rounds per client")
    args = ap.parse_args(argv)
    if args.clients < 2:
        ap.error(f"--clients must be >= 2, got {args.clients}")

    import numpy as np

    from measured_autotune import small_cfg
    from repro.api import TuningService
    from repro.serving import QueueFull

    cfg = small_cfg()

    # -- phase 1: nominal load — N clients, p99 inside the SLO --------------
    with TuningService(cfg, serving={"slo_ms": args.slo_ms}) as svc:
        print(f"== serving: {args.clients} concurrent clients, "
              f"slo {args.slo_ms:.0f} ms ==")
        pairs = [(svc.open_session(agent="brute", oracle="model"),
                  client_sites(i)) for i in range(args.clients)]
        for s, ss in pairs:
            s.fit(ss)
        # warm round: the fused route's jit trace + compile, paid once —
        # both pad buckets (the full coalesced batch and a solo/partial
        # batch), so no measured round ever traces
        for f in [s.tune_async(ss) for s, ss in pairs]:
            f.result(timeout=300)
        pairs[0][0].tune(pairs[0][1])

        lat, errors = [], []
        barrier = threading.Barrier(args.clients)
        lock = threading.Lock()

        def client(sess, ss):
            try:
                for _ in range(args.rounds):
                    barrier.wait()           # rounds arrive together:
                    t0 = time.perf_counter()  # the batcher's job
                    prog = sess.tune(ss)
                    dt = time.perf_counter() - t0
                    assert len(prog.tiles) == len(ss)
                    with lock:
                        lat.append(dt)
            except Exception as e:           # pragma: no cover - surfaced
                errors.append(e)
                barrier.abort()              # release waiting peers

        threads = [threading.Thread(target=client, args=p) for p in pairs]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        if errors:
            raise errors[0]

        st = svc.server.stats()
        p50 = float(np.percentile(lat, 50)) * 1e3
        p99 = float(np.percentile(lat, 99)) * 1e3
        ok = p99 <= args.slo_ms
        print(f"serving: {len(lat)} tunes, p50 {p50:.2f} ms, "
              f"p99 {p99:.2f} ms (slo {args.slo_ms:.0f} ms) — "
              f"within SLO: {'OK' if ok else 'MISS'}")
        print(f"shed: {st['serving_shed_total']}, deadline misses: "
              f"{st['serving_deadline_misses_total']}, batches: "
              f"{st['serving_batches_total']}, fused dispatches: "
              f"{st['serving_fused_dispatches_total']} "
              f"(largest batch {st['serving_batch_requests_max']} requests)")
        print(f"health: {svc.server.health()}")
        snap = svc.registry.snapshot()
        n_series = sum(1 for k in snap if k.startswith("serving_"))
        print(f"obs: {n_series} serving_* metric series in the registry")
        assert ok, f"p99 {p99:.2f} ms blew the {args.slo_ms:.0f} ms SLO"
        assert st["serving_shed_total"] == 0, st

    # -- phase 2: overload — admission control sheds, typed ------------------
    burst = 16
    with TuningService(cfg, serving={"slo_ms": 60_000.0, "max_queue": 2,
                                     "max_wait_ms": 250.0}) as svc:
        s = svc.open_session(agent="brute", oracle="model")
        ss = client_sites(0)
        s.fit(ss)
        futs, shed = [], 0
        for _ in range(burst):               # queue holds 2; rest shed
            try:
                futs.append(s.tune_async(ss))
            except QueueFull:
                shed += 1
        for f in futs:                       # every ADMITTED request lands
            assert len(f.result(timeout=300).tiles) == len(ss)
        print(f"overload: shed={shed} of {burst} burst requests at "
              f"max_queue=2 (typed QueueFull), {len(futs)} admitted — "
              f"all served, health {svc.server.health()}")
        assert shed > 0, "burst never tripped admission control"
    return lat


if __name__ == "__main__":
    main()
