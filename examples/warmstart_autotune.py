"""Warm-start autotuning from persistent artifacts (``repro.artifacts``).

The paper's deployment story (§4): train once, then *greedy inference
only* on new code.  PR 5 makes the trained artifact survive the process —
this script is the proof, split across two invocations so the warm phase
genuinely runs in a fresh process (exactly how CI drives it):

    # phase 1: fit, save the facade artifact, record the cold decisions
    PYTHONPATH=src python examples/warmstart_autotune.py --phase fit \
        --artifact /tmp/nv_artifact --store /tmp/nv_programs.jsonl

    # phase 2 (fresh process): load, tune twice through the ProgramStore
    PYTHONPATH=src python examples/warmstart_autotune.py --phase warm \
        --artifact /tmp/nv_artifact --store /tmp/nv_programs.jsonl

The warm phase asserts the acceptance invariant end to end:

* the loaded facade's tile program is **bitwise-identical** to the one
  tuned before saving (cross-process round trip);
* the first warm tune is already a ``ProgramStore`` **lookup** when the
  fit phase shared the store (zero agent inferences in this process);
* the second tune of the same site set performs **0 agent inferences**
  (grep the ``tune 2: agent inferences 0`` line).
"""
import argparse
import sys

sys.path.insert(0, "src")


def small_cfg():
    from repro.api import NeuroVecConfig
    return NeuroVecConfig(train_batch=32, sgd_minibatch=16, ppo_epochs=2,
                          lr=5e-4)


def demo_sites():
    from repro.models.compute import KernelSite
    return [
        KernelSite(site="ws.qkv", kind="matmul", m=64, n=128, k=256),
        KernelSite(site="ws.ffn", kind="matmul", m=128, n=128, k=128),
        KernelSite(site="ws.attn", kind="attention", m=128, n=64, k=128,
                   batch=2, causal=True),
        KernelSite(site="ws.scan", kind="chunk_scan", m=64, n=32, k=16,
                   batch=2),
    ]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--phase", choices=("fit", "warm"), required=True)
    ap.add_argument("--artifact", default="/tmp/repro_nv_artifact",
                    help="facade artifact directory (nv.save/load)")
    ap.add_argument("--store", default="/tmp/repro_nv_programs.jsonl",
                    help="shared ProgramStore path")
    ap.add_argument("--agent", default="ppo")
    ap.add_argument("--steps", type=int, default=96,
                    help="PPO budget for --phase fit")
    ap.add_argument("--expect", default="/tmp/repro_nv_cold_tiles.json",
                    help="cold tile program recorded by fit, verified "
                         "bitwise by warm")
    args = ap.parse_args(argv)

    from repro.api import NeuroVectorizer, TileProgram

    sites = demo_sites()

    if args.phase == "fit":
        nv = NeuroVectorizer(small_cfg(), agent=args.agent, seed=0,
                             program_store=args.store)
        fit_kw = {"total_steps": args.steps} if args.agent == "ppo" else {}
        nv.fit(sites, **fit_kw)
        prog = nv.tune_sites(sites)
        prog.save(args.expect)
        fp = nv.save(args.artifact)
        print(f"== cold fit: {args.agent}, {len(prog.tiles)} sites tuned, "
              f"{nv.agent_inferences} agent inferences ==")
        print(f"saved facade artifact -> {args.artifact} "
              f"(agent fingerprint {fp[:16]})")
        print(f"cold tiles -> {args.expect}; store -> {args.store}")
        nv.close()
        return prog

    # -- phase warm: a FRESH process restores everything --------------------
    nv = NeuroVectorizer.load(args.artifact, program_store=args.store)
    print(f"== warm start: loaded {args.artifact} "
          f"(agent={nv.agent.name}) ==")

    prog1 = nv.tune_sites(sites)
    print(f"tune 1: agent inferences {nv.agent_inferences}, "
          f"store hits {nv.store_hits}, misses {nv.store_misses}")
    before = nv.agent_inferences
    prog2 = nv.tune_sites(sites)
    print(f"tune 2: agent inferences {nv.agent_inferences - before}, "
          f"store hits {nv.store_hits}, misses {nv.store_misses}")

    assert prog2.tiles == prog1.tiles, "second tune diverged"
    assert nv.agent_inferences - before == 0, \
        "second tune of a stored site set must perform zero inferences"
    expect = TileProgram.load(args.expect)
    assert prog1.tiles == expect.tiles, (
        f"cross-process round-trip broke: {prog1.tiles} != {expect.tiles}")
    print("round-trip invariant: OK (warm tiles bitwise-equal to cold "
          "tiles from the fit process)")
    st = nv.program_store.stats()
    print(f"program store: {st['entries']} entries, hit rate "
          f"{st['hit_rate']:.2f}")
    nv.close()
    return prog1


if __name__ == "__main__":
    main()
