"""Quickstart: the NeuroVectorizer loop in miniature (paper Fig. 3),
driven entirely through the ``repro.api`` facade.

Extract kernel sites from a model -> fit the PPO bandit on a synthetic
corpus -> tune the sites -> inject the tile program -> verify the tuned
kernels compute the same numbers and the modelled TPU time improved.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.api import NeuroVectorizer, NeuroVecConfig, extract_arch_sites
from repro.core import dataset
from repro.models import compute
from repro.models.compute import KernelSite


def main():
    cfg = NeuroVecConfig(train_batch=500, sgd_minibatch=125, ppo_epochs=6)
    nv = NeuroVectorizer(cfg, agent="ppo", lr=5e-4, seed=0)

    print("== 1. extract kernel sites (the 'loop extractor') ==")
    sites = extract_arch_sites("qwen3_8b", batch=8, seq=2048)
    for s in sites[:5]:
        print("  ", s.key())
    print(f"  ... {len(sites)} sites total")

    print("== 2. fit the deep-RL agent on a synthetic corpus ==")
    corpus = dataset.generate(1500, seed=0, base=sites)
    nv.fit(corpus, total_steps=5000)
    hist = nv.agent.history
    print(f"  reward mean: {hist[0]['reward_mean']:+.3f} -> "
          f"{hist[-1]['reward_mean']:+.3f}  (positive = beats baseline)")

    print("== 3. tune the extracted sites (inference mode) ==")
    prog = nv.tune_sites(sites)
    sp = nv.speedup(prog, sites)
    print(f"  modelled speedup over heuristic baseline: {sp:.2f}x")

    print("== 4. inject: same math through tuned Pallas kernels ==")
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 512))
    site = KernelSite(site="demo", kind="matmul", m=128, n=512, k=256,
                      dtype="float32")
    demo_prog = nv.tune_sites([site])
    y_ref = compute.matmul(x, w, site="demo")
    with nv.inject(demo_prog, interpret=True):
        y_tuned = compute.matmul(x, w, site="demo")
    err = float(jnp.max(jnp.abs(y_tuned - y_ref)))
    print(f"  tiles={demo_prog.tiles[site.key()]}  max |diff| = {err:.2e}")
    assert err < 1e-4
    print("quickstart OK")


if __name__ == "__main__":
    main()
