"""Serving with the production substrate: batched KV-cache decode, straggler
monitoring, graceful preemption, an elastic re-plan after a simulated
chip failure — and a NeuroVectorizer tile plan for the serving kernels via
the ``repro.api`` facade.

    PYTHONPATH=src python examples/fault_tolerant_serving.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.api import NeuroVectorizer, extract_sites
from repro.configs import get_config
from repro.ft.monitor import StepMonitor, plan_elastic_mesh
from repro.models.lm import build_model
from repro.train.steps import make_prefill_step, make_serve_step


def main():
    cfg = get_config("jamba_v0_1_52b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, prompt, gen = 4, 16, 12
    ctx = prompt + gen

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (B, prompt), 0, cfg.vocab_size,
                                          jnp.int32)}
    cache = model.make_cache(B, ctx, jnp.dtype(cfg.dtype))
    prefill = jax.jit(make_prefill_step(model))
    serve = jax.jit(make_serve_step(model), donate_argnums=(3,))

    print("== tile plan for the serving step (repro.api facade) ==")
    sites = extract_sites(make_prefill_step(model), params, batch, cache)
    nv = NeuroVectorizer(agent="brute")       # exhaustive: few serve sites
    prog = nv.fit(sites).tune_sites(sites)
    print(f"  {len(prog.tiles)} sites tuned; modelled speedup "
          f"{nv.speedup(prog, sites):.2f}x (inject on TPU via nv.inject)")

    print("== batched decode with straggler monitoring ==")
    mon = StepMonitor(warmup=3, z_thresh=3.0)
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for i in range(gen - 1):
        mon.start()
        tok, _, cache = serve(params, tok, jnp.int32(prompt + i), cache)
        ev = mon.stop(i)
        if ev:
            print(f"  straggler flagged at step {i}: z={ev['z']:.1f}")
    print(f"  decoded {gen} tokens/request; mean step "
          f"{mon.mean*1e3:.1f} ms; {len(mon.events)} straggler events")

    print("== elastic re-plan after simulated failures ==")
    for healthy in (256, 248, 192, 130):
        p = plan_elastic_mesh(healthy_chips=healthy, model_parallel=16,
                              global_batch=128)
        print(f"  {healthy:4d} healthy chips -> mesh {p.mesh_shape}, "
              f"drop {p.dropped_chips}, global_batch {p.global_batch}")
    print("serving example OK")


if __name__ == "__main__":
    main()
